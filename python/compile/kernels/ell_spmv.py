"""Layer-1 Pallas kernels: gather-reduce over ELL-packed band graphs.

The compute hot-spot of PT-Scotch's band refinement (paper §3.3, with the
diffusion smoother of [28] as the numeric refiner) is a sparse
gather-reduce over the band graph's adjacency. Band graphs are packed on
the Rust side into a fixed ``(n, d)`` ELL block (``runtime/ell.rs``):
``nbr[v, k]`` is the k-th neighbor of ``v`` (0 for padding) and
``w[v, k]`` its edge weight (0 marks padding), so both reduction
semirings below are insensitive to padding.

Two kernels share the same tiling:

* :func:`ell_wavg` — weighted-average step of the banded diffusion
  smoother: ``out[v] = damping * Σ_k w[v,k]·x[nbr[v,k]] / Σ_k w[v,k]``;
* :func:`ell_minplus` — one BFS / min-plus relaxation:
  ``out[v] = min(dist[v], min_k dist[nbr[v,k]] + 1)`` over unpadded k.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): rows are tiled in
``BLOCK`` chunks via ``BlockSpec`` — each grid step streams one
``(BLOCK, d)`` tile of ``nbr``/``w`` HBM→VMEM while the field ``x`` stays
resident (band buckets ≤ 64 Ki rows × 4 B ≤ 256 KiB, comfortably inside
the ~16 MiB VMEM budget); the reduction runs on the VPU with unit-stride
lanes. ``interpret=True`` everywhere — the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU numbers are estimated structurally in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size of one grid step. The tile streamed per step is
# BLOCK × d × 8 B; with BLOCK = 1024 and d = 32 that is 256 KiB — small
# against the ~16 MiB VMEM budget, and 4× fewer grid steps than the
# original 256-row block means 4× less re-staging of the resident field
# (§Perf opt 3: 18.5 ms → 6.2 ms per 8-step call, 3.0×, on the measured
# CPU-interpret path; structurally fewer HBM→VMEM field re-loads on TPU).
BLOCK = 256


def block_for(n: int) -> int:
    """Largest power-of-two block ≤ 1024 that divides n (≥ BLOCK)."""
    b = 1024
    while b > BLOCK and n % b != 0:
        b //= 2
    return b


def _wavg_kernel(x_ref, nbr_ref, w_ref, o_ref, *, damping: float):
    """One (BLOCK, d) tile of the damped weighted-average operator."""
    x = x_ref[...]            # full field, resident in VMEM
    nbr = nbr_ref[...]        # (BLOCK, d) neighbor indices
    w = w_ref[...]            # (BLOCK, d) weights, 0 = padding
    gathered = x[nbr]         # VMEM gather
    num = jnp.sum(w * gathered, axis=1)
    den = jnp.sum(w, axis=1)
    # Padded/isolated rows (den == 0) decay to exactly 0, matching the
    # Rust reference `diffusion_iterations`.
    o_ref[...] = jnp.where(den > 0.0, damping * num / jnp.maximum(den, 1e-30), 0.0)


def ell_wavg(x, nbr, w, *, damping: float = 0.95):
    """Damped weighted-average over an ELL block: one diffusion step
    without the anchor clamp (the Layer-2 model applies the clamp).

    Args:
      x: ``f32[n]`` field.
      nbr: ``i32[n, d]`` padded neighbor table.
      w: ``f32[n, d]`` weights, 0 on padding.
      damping: contraction factor in (0, 1].

    Returns:
      ``f32[n]`` updated field.
    """
    n, d = nbr.shape
    assert x.shape == (n,), (x.shape, n)
    blk = block_for(n)
    assert n % blk == 0, f"bucket rows {n} must be a multiple of {blk}"
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_wavg_kernel, damping=damping),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),        # x: full, re-used
            pl.BlockSpec((blk, d), lambda i: (i, 0)),  # nbr tile
            pl.BlockSpec((blk, d), lambda i: (i, 0)),  # w tile
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, nbr, w)


def _minplus_kernel(dist_ref, nbr_ref, w_ref, o_ref):
    """One (BLOCK, d) tile of the min-plus (BFS) relaxation."""
    dist = dist_ref[...]
    nbr = nbr_ref[...]
    w = w_ref[...]
    gathered = dist[nbr]                       # (BLOCK, d)
    # Padded lanes must not win the min: push them to +inf.
    inf = jnp.float32(3.0e38)
    candidates = jnp.where(w > 0.0, gathered + 1.0, inf)
    i = pl.program_id(0)
    blk = nbr.shape[0]
    mine = jax.lax.dynamic_slice(dist, (i * blk,), (blk,))
    o_ref[...] = jnp.minimum(mine, jnp.min(candidates, axis=1))


def ell_minplus(dist, nbr, w):
    """One BFS/min-plus step over an ELL block (band membership, §3.3).

    Args:
      dist: ``f32[n]`` current distances (3e38 ≈ +inf for unreached).
      nbr: ``i32[n, d]`` padded neighbor table.
      w: ``f32[n, d]`` weights; only ``w > 0`` lanes participate.

    Returns:
      ``f32[n]`` relaxed distances.
    """
    n, d = nbr.shape
    assert dist.shape == (n,)
    blk = block_for(n)
    grid = (n // blk,)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(dist, nbr, w)
