"""AOT emitter: lower the Layer-2 models to HLO **text** artifacts.

Run once at build time (``make artifacts``); the Rust runtime compiles
and executes the text modules through PJRT. HLO text — not
``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts`` (from ``python/``).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

#: Size buckets emitted by default: (rows, max degree). Rows must be
#: multiples of the kernel BLOCK (256). Two consumers share them:
#: the sequential refiner packs whole centralized bands, and the
#: distributed path (``dist::ddiffusion``) packs one rank's band slice
#: — local *plus ghost* rows — so the ladder includes small steps
#: (256/512/1024) sized for per-rank slices of bands split over 2–16
#: ranks, not just whole-band sizes. Graphs bigger than the largest
#: bucket fall back to the CPU reference at run time.
BUCKETS = [(256, 32), (512, 32), (1024, 32), (4096, 32), (16384, 32)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, buckets=None) -> list:
    """Lower every (kernel, bucket) pair; returns manifest rows."""
    buckets = buckets or BUCKETS
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for n, d in buckets:
        for kernel, fn, k in [
            ("diffusion", model.diffusion_steps, model.STEPS_PER_CALL),
            ("minplus", model.minplus_step, 1),
        ]:
            args = model.example_args(n, d, kernel)
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{kernel}_n{n}_d{d}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            rows.append((kernel, n, d, k, fname))
            print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# kernel n d steps file\n")
        for r in rows:
            f.write(" ".join(str(x) for x in r) + "\n")
    print(f"manifest: {len(rows)} artifacts in {out_dir}")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--small",
        action="store_true",
        help="emit only the smallest bucket (fast CI smoke)",
    )
    ns = ap.parse_args()
    buckets = BUCKETS[:1] if ns.small else BUCKETS
    emit(ns.out, buckets)


if __name__ == "__main__":
    main()
