"""Make `pytest python/tests/` work from the repo root: the compile
package lives one directory up from the tests."""
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
