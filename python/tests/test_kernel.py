"""Kernel-vs-oracle correctness — the core build-time signal.

The Pallas kernels (interpret mode) must agree with the pure-jnp oracles
to float32 tolerance over hypothesis-generated ELL blocks, and the fused
L2 diffusion model must agree with the step-by-step reference.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ell_spmv, ref


def random_ell(rng, n, d, frac_pad_rows=0.2):
    """A random symmetric-ish ELL block with padded lanes and rows."""
    nbr = rng.integers(0, n, size=(n, d), dtype=np.int32)
    w = rng.uniform(0.5, 3.0, size=(n, d)).astype(np.float32)
    # Random padding: zero out a suffix of each row.
    keep = rng.integers(0, d + 1, size=n)
    lane = np.arange(d)[None, :]
    mask = lane < keep[:, None]
    w = np.where(mask, w, 0.0).astype(np.float32)
    nbr = np.where(mask, nbr, 0).astype(np.int32)
    # Some fully padded rows (like bucket padding).
    pad_rows = rng.random(n) < frac_pad_rows
    w[pad_rows] = 0.0
    nbr[pad_rows] = 0
    return jnp.asarray(nbr), jnp.asarray(w)


# ----- fixed-size deterministic checks ---------------------------------


def test_wavg_matches_ref_fixed():
    rng = np.random.default_rng(0)
    n, d = 256, 8
    nbr, w = random_ell(rng, n, d)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = ell_spmv.ell_wavg(x, nbr, w, damping=0.9)
    want = ref.ell_wavg_ref(x, nbr, w, damping=0.9)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_minplus_matches_ref_fixed():
    rng = np.random.default_rng(1)
    n, d = 256, 8
    nbr, w = random_ell(rng, n, d)
    dist = np.full(n, 3.0e38, dtype=np.float32)
    dist[rng.integers(0, n, size=10)] = 0.0
    got = ell_spmv.ell_minplus(jnp.asarray(dist), nbr, w)
    want = ref.ell_minplus_ref(jnp.asarray(dist), nbr, w)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_minplus_fixed_point_reaches_bfs():
    """Iterated min-plus equals BFS distances on a ring graph."""
    n, d = 256, 2
    nbr = np.zeros((n, d), dtype=np.int32)
    w = np.ones((n, d), dtype=np.float32)
    for v in range(n):
        nbr[v, 0] = (v - 1) % n
        nbr[v, 1] = (v + 1) % n
    dist = np.full(n, 3.0e38, dtype=np.float32)
    dist[0] = 0.0
    x = jnp.asarray(dist)
    for _ in range(n // 2 + 1):
        x = ell_spmv.ell_minplus(x, jnp.asarray(nbr), jnp.asarray(w))
    x = np.asarray(x)
    for v in range(n):
        assert x[v] == min(v, n - v), f"vertex {v}: {x[v]}"


def test_diffusion_model_matches_ref():
    rng = np.random.default_rng(2)
    n, d = 256, 8
    nbr, w = random_ell(rng, n, d)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    mask = np.zeros(n, dtype=np.float32)
    vals = np.zeros(n, dtype=np.float32)
    mask[[3, 7]] = 1.0
    vals[3], vals[7] = -1.0, 1.0
    got = model.diffusion_steps(x, jnp.asarray(mask), jnp.asarray(vals), nbr, w)[0]
    want = ref.diffusion_ref(
        x,
        jnp.asarray(mask),
        jnp.asarray(vals),
        nbr,
        w,
        steps=model.STEPS_PER_CALL,
        damping=model.DAMPING,
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # Anchors stay clamped.
    assert got[3] == -1.0 and got[7] == 1.0


def test_diffusion_contracts_field():
    """With damping < 1 and no anchors the field decays toward 0."""
    rng = np.random.default_rng(3)
    n, d = 256, 4
    nbr, w = random_ell(rng, n, d, frac_pad_rows=0.0)
    x = jnp.asarray(rng.uniform(-1, 1, n).astype(np.float32))
    zeros = jnp.zeros(n, dtype=jnp.float32)
    out = model.diffusion_steps(x, zeros, zeros, nbr, w)[0]
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(x))) + 1e-6


# ----- hypothesis sweeps ------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(1, 16),
    damping=st.floats(0.5, 1.0),
)
def test_wavg_hypothesis(seed, d, damping):
    rng = np.random.default_rng(seed)
    n = 256  # one BLOCK — shape sweep is over d and contents
    nbr, w = random_ell(rng, n, d)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = ell_spmv.ell_wavg(x, nbr, w, damping=damping)
    want = ref.ell_wavg_ref(x, nbr, w, damping=damping)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 16))
def test_minplus_hypothesis(seed, d):
    rng = np.random.default_rng(seed)
    n = 256
    nbr, w = random_ell(rng, n, d)
    dist = rng.uniform(0, 50, n).astype(np.float32)
    got = ell_spmv.ell_minplus(jnp.asarray(dist), nbr, w)
    want = ref.ell_minplus_ref(jnp.asarray(dist), nbr, w)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(blocks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_wavg_multiblock_grid(blocks, seed):
    """The BlockSpec tiling must be seam-free across grid steps."""
    rng = np.random.default_rng(seed)
    n, d = 256 * blocks, 6
    nbr, w = random_ell(rng, n, d)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = ell_spmv.ell_wavg(x, nbr, w)
    want = ref.ell_wavg_ref(x, nbr, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


# ----- AOT bridge smoke -------------------------------------------------


def test_aot_emit_small(tmp_path):
    """The emitter produces parseable HLO text and a manifest."""
    from compile import aot

    rows = aot.emit(str(tmp_path), buckets=[(256, 8)])
    assert len(rows) == 2  # diffusion + minplus
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "diffusion 256 8" in manifest
    hlo = (tmp_path / "diffusion_n256_d8.hlo.txt").read_text()
    assert "HloModule" in hlo
    assert "f32[256]" in hlo


def test_lowered_diffusion_runs_and_matches(tmp_path):
    """Execute the lowered computation via jax and compare to the model
    (guards against lowering-time semantic drift)."""
    import jax

    n, d = 256, 8
    rng = np.random.default_rng(7)
    nbr, w = random_ell(rng, n, d)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    zeros = jnp.zeros(n, dtype=jnp.float32)
    compiled = jax.jit(model.diffusion_steps).lower(x, zeros, zeros, nbr, w).compile()
    got = compiled(x, zeros, zeros, nbr, w)[0]
    want = model.diffusion_steps(x, zeros, zeros, nbr, w)[0]
    np.testing.assert_allclose(got, want, rtol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
